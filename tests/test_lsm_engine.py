"""LSM engine vs python-dict oracle, across all four codecs.

Covers: put/get/delete/update semantics, range lookups, filters with
stale-version shadowing, MVCC snapshots, compaction invariants
(single sorted run per level, dense codes, monotone disk layout)."""

import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core import LSMConfig, LSMTree, Predicate

VW = 32
CODECS = ["opd", "plain", "heavy", "blob"]


def small_cfg(codec):
    return LSMConfig(codec=codec, value_width=VW, file_bytes=64 * 1024,
                     l0_limit=2, size_ratio=3, max_levels=5)


def val(i):
    return (b"pfx_%03d_" % (i % 50)) + b"x" * 10


@pytest.mark.parametrize("codec", CODECS)
def test_crud_vs_oracle(codec):
    rng = np.random.default_rng(7)
    t = LSMTree(small_cfg(codec))
    oracle = {}
    for _ in range(9000):
        k = int(rng.integers(0, 2000))
        op = rng.random()
        if op < 0.75:
            v = val(int(rng.integers(0, 1000)))
            t.put(k, v)
            oracle[k] = v
        else:
            t.delete(k)
            oracle.pop(k, None)
    probe = rng.integers(0, 2200, 300)
    for k in probe:
        got = t.get(int(k))
        exp = oracle.get(int(k))
        if exp is None:
            assert got is None, (codec, k)
        else:
            assert got is not None and got.rstrip(b"\x00") == exp, (codec, k)


@pytest.mark.parametrize("codec", CODECS)
def test_range_lookup_vs_oracle(codec):
    rng = np.random.default_rng(3)
    t = LSMTree(small_cfg(codec))
    oracle = {}
    for _ in range(6000):
        k = int(rng.integers(0, 3000))
        v = val(int(rng.integers(0, 500)))
        t.put(k, v)
        oracle[k] = v
    lo, hi = 500, 1500
    keys, values = t.range_lookup(lo, hi)
    exp_keys = sorted(k for k in oracle if lo <= k <= hi)
    assert keys.tolist() == exp_keys
    for k, v in zip(keys.tolist(), values):
        assert bytes(v).rstrip(b"\x00") == oracle[k]


@pytest.mark.parametrize("codec", CODECS)
def test_filter_with_shadowing(codec):
    """A newer non-matching version must shadow an older matching one."""
    t = LSMTree(small_cfg(codec))
    n = 4000
    for i in range(n):
        t.put(i, b"match_me" + b"a" * 10)
    t.flush()
    # overwrite a subset with non-matching values (newer versions)
    for i in range(0, n, 10):
        t.put(i, b"other_value" + b"b" * 8)
    # and delete another subset
    for i in range(5, n, 10):
        t.delete(i)
    res = t.filter(Predicate("prefix", b"match_me"))
    got = set(res.keys.tolist())
    exp = {i for i in range(n) if i % 10 != 0 and i % 10 != 5}
    assert got == exp, (codec, len(got), len(exp))


def test_all_runs_l0_order_newest_first():
    """Regression: ``all_runs(newest_first)`` must honor its parameter.
    L0 read order after multiple flushes is newest-first (shadowing
    depends on it); ``newest_first=False`` yields oldest-first."""
    t = LSMTree(LSMConfig(codec="opd", value_width=VW, file_bytes=64 * 1024,
                          l0_limit=10, size_ratio=3, max_levels=5))
    for rnd in range(4):
        for k in range(40):
            t.put(k, val(rnd))
        t.flush()
    n_l0 = len(t.levels[0])
    assert n_l0 >= 4 and t.n_compactions == 0
    runs = t.all_runs()
    l0_seqs = [s.max_seqno for s in runs[:n_l0]]
    assert l0_seqs == sorted(l0_seqs, reverse=True)  # newest -> oldest
    rev = t.all_runs(newest_first=False)
    assert [s.file_id for s in rev[:n_l0]] == \
        [s.file_id for s in reversed(runs[:n_l0])]
    assert [s.file_id for s in rev[n_l0:]] == [s.file_id for s in runs[n_l0:]]
    # the newest version must win on read (first-match-wins over L0)
    assert t.get(0).rstrip(b"\x00") == val(3)


def test_mvcc_snapshot_isolation():
    t = LSMTree(small_cfg("opd"))
    for i in range(3000):
        t.put(i, b"v1_" + bytes([65 + i % 26]) * 5)
    snap = t.snapshot()
    for i in range(3000):
        t.put(i, b"v2_" + bytes([65 + i % 26]) * 5)
    t.flush()
    # snapshot still sees v1
    assert t.get(100, snap).startswith(b"v1_")
    assert t.get(100).startswith(b"v2_")
    res_old = t.filter(Predicate("prefix", b"v1_"), snap)
    res_new = t.filter(Predicate("prefix", b"v1_"))
    assert res_old.keys.shape[0] == 3000
    assert res_new.keys.shape[0] == 0


def test_leveling_invariants_and_density():
    t = LSMTree(small_cfg("opd"))
    rng = np.random.default_rng(0)
    for _ in range(20000):
        t.put(int(rng.integers(0, 10000)), val(int(rng.integers(0, 300))))
    for lvl in range(1, t.cfg.max_levels):
        scts = t.levels[lvl]
        # single sorted run: non-overlapping key ranges
        for a, b in zip(scts, scts[1:]):
            assert a.max_key < b.min_key
        for s in scts:
            # keys sorted
            assert np.all(np.diff(s.keys.astype(np.int64)) >= 0)
            # codes dense in [0, D): every dict entry referenced
            live = s.evs[~s.tombs]
            if live.size:
                assert live.min() >= 0 and live.max() == s.opd.size - 1
                assert np.array_equal(np.unique(live), np.arange(s.opd.size))
            # order-preserving after compaction remaps
            vals = s.opd.decode(np.clip(s.evs, 0, None))
            order = np.argsort(s.evs[~s.tombs], kind="stable")
            sv = vals[~s.tombs][order]
            assert np.array_equal(sv, np.sort(sv))


def test_opd_denser_than_plain():
    """Figure 4: OPD => fewer disk bytes and fewer files than plain."""
    trees = {}
    for codec in ("opd", "plain"):
        t = LSMTree(small_cfg(codec))
        rng = np.random.default_rng(1)
        for _ in range(15000):
            t.put(int(rng.integers(0, 8000)), val(int(rng.integers(0, 200))))
        trees[codec] = t
    assert trees["opd"].disk_bytes < 0.6 * trees["plain"].disk_bytes
    assert trees["opd"].n_files <= trees["plain"].n_files
    assert trees["opd"].n_compactions <= trees["plain"].n_compactions


@given(st.lists(st.tuples(st.integers(0, 400), st.integers(0, 80),
                          st.booleans()), min_size=1, max_size=600))
@settings(max_examples=15, deadline=None)
def test_property_random_ops_opd(ops):
    t = LSMTree(LSMConfig(codec="opd", value_width=VW, file_bytes=8 * 1024,
                          l0_limit=2, size_ratio=2, max_levels=5))
    oracle = {}
    for k, vi, is_del in ops:
        if is_del:
            t.delete(k)
            oracle.pop(k, None)
        else:
            t.put(k, val(vi))
            oracle[k] = val(vi)
    for k in {k for k, _, _ in ops}:
        got = t.get(k)
        exp = oracle.get(k)
        assert (got is None) == (exp is None)
        if exp is not None:
            assert got.rstrip(b"\x00") == exp
    res = t.filter(Predicate("prefix", b"pfx_00"))
    exp_keys = sorted(k for k, v in oracle.items()
                      if v.startswith(b"pfx_00"))
    assert sorted(res.keys.tolist()) == exp_keys
